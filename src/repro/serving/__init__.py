from .serve import make_prefill_step, make_decode_step, init_cache  # noqa: F401
from .serve import BucketedPrefill  # noqa: F401
from .service import (  # noqa: F401
    Completion,
    DeadlineExceeded,
    Endpoint,
    EndpointClosed,
    Overloaded,
    ServingError,
    serve,
)
