"""Serving steps: batched prefill and single-token decode.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
one new token against a seq_len-sized cache. Sliding-window layers carry
window-sized caches; MLA carries the compressed (c_kv, k_rope) cache; SSM
layers carry (conv window, state) — each O(1) or O(window) per step.

``BucketedPrefill`` is the session-backed bucketing engine underneath the
serving front door: one compiled executable per (batch, seq) bucket, held
in a ``repro.Database`` session's executable cache with LRU eviction
(``max_entries``) and a ``warmup(buckets=...)`` sweep, so traffic at
mixed shapes never recompiles on the request path. It is an internal
detail of ``serving.service.Endpoint`` (``db.endpoint`` /
``repro.serve``) — the async request path with continuous batching,
decode-step bucketing and load shedding lives there.
"""

from __future__ import annotations

import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model, stages_of


def _attn_cache_entry(cfg, kind: str, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.hd() if cfg.n_heads else 0
    if kind in ("mla", "mla_moe"):
        return {
            "kv": {
                "c": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
                "r": jnp.zeros((batch, cache_len, cfg.rope_head_dim), dt),
            }
        }
    if kind in ("attn", "global", "moe", "dec"):
        return {
            "kv": {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "local":
        w = min(cfg.window or cache_len, cache_len)
        return {
            "kv": {
                "k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "mamba1":
        c = cfg.ssm_expand * cfg.d_model
        return {
            "ssm1": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c), dt),
                "ssm": jnp.zeros((batch, c, cfg.ssm_state), jnp.float32),
            }
        }
    if kind in ("mamba2", "mamba2_attn"):
        c = cfg.ssm_expand * cfg.d_model
        nh = c // cfg.ssm_head_dim
        entry = {
            "ssm2": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c + 2 * cfg.ssm_state), dt),
                "ssm": jnp.zeros(
                    (batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
                ),
            }
        }
        if kind == "mamba2_attn":
            entry["shared_kv"] = {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        return entry
    raise ValueError(kind)


def init_cache(cfg, batch: int, cache_len: int):
    """Zero-initialized cache pytree matching Model._run_stages structure."""
    caches = []
    for st in stages_of(cfg):
        entry = {
            f"{i}:{kind}": _attn_cache_entry(cfg, kind, batch, cache_len)
            for i, kind in enumerate(st.pattern)
        }
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (st.repeats,) + x.shape), entry
        )
        tail = [
            _attn_cache_entry(cfg, kind, batch, cache_len) for kind in st.tail
        ]
        caches.append({"scan": stacked, "tail": tail})
    return caches


def _param_shardings(model: Model, mesh):
    """NamedSharding tree for the model's parameters on ``mesh`` (the
    launch/sharding.py planner layout)."""
    from repro.launch.sharding import param_pspecs, to_shardings

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # FSDP needs a "data" axis; the 1-axis host-mesh fallback still gets
    # the tensor-parallel rules.
    specs = param_pspecs(params_shape, mesh, fsdp="data" in mesh.axis_names)
    return to_shardings(specs, mesh)


def make_prefill_step(model: Model, cache_len: int, *, mesh=None, db=None):
    """``mesh`` (a jax Mesh or a ``launch/mesh.resolve_mesh`` spec string
    such as ``"host"`` / ``"production"``) returns the step jitted with
    the launch/sharding.py parameter layout — ``make_host_mesh`` /
    ``make_production_mesh`` are the canonical constructors. ``db``
    (a ``repro.Database``) supplies the mesh from the session instead;
    ``BucketedPrefill`` is the bucketed front end over this."""
    if db is not None and mesh is None:
        mesh = db.mesh
    from repro.launch.mesh import resolve_mesh

    mesh = resolve_mesh(mesh)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)

    if mesh is None:
        return prefill_step
    return jax.jit(prefill_step, in_shardings=(_param_shardings(model, mesh), None))


class _StrongRef:
    """Callable strong-reference fallback for anchors that reject
    weakrefs — the LRU capacity still bounds what it can pin."""

    __slots__ = ("_obj",)

    def __init__(self, obj):
        self._obj = obj

    def __call__(self):
        return self._obj


class _PlacedParamsCache:
    """Bounded placement cache for device_put-placed parameter pytrees.

    Entries are keyed on a **(weakref, id) identity pair**: ``id(params)``
    indexes the cache, and a weak reference to the pytree's first array
    leaf validates the hit (two distinct pytrees can recycle the same
    ``id`` across garbage collections — the live-leaf identity check makes
    that impossible to alias). Entries are evicted three ways: the weakref
    callback drops an entry the moment its source params die (so a
    long-running server never pins placed copies of stale params — the
    historical leak: the cache held the *source* params strongly and
    keyed on a never-evicted ``id``), LRU order bounds the cache at
    ``capacity``, and ``clear()`` empties it."""

    def __init__(self, capacity: int = 4):
        self.capacity = capacity
        self._entries: "OrderedDict[int, Tuple[Callable, Any]]" = (
            OrderedDict()
        )

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()

    @staticmethod
    def _anchor(params):
        leaves = jax.tree_util.tree_leaves(params)
        return leaves[0] if leaves else params

    def place(self, params, shardings):
        """The ``device_put(params, shardings)`` copy, cached per live
        params object."""
        key = id(params)
        anchor = self._anchor(params)
        hit = self._entries.get(key)
        if hit is not None and hit[0]() is anchor:
            self._entries.move_to_end(key)
            return hit[1]
        placed = jax.device_put(params, shardings)
        entries = self._entries

        def _on_death(ref, _key=key):
            ent = entries.get(_key)
            if ent is not None and ent[0] is ref:
                del entries[_key]

        try:
            ref: Callable = weakref.ref(anchor, _on_death)
        except TypeError:
            ref = _StrongRef(anchor)
        entries[key] = (ref, placed)
        entries.move_to_end(key)
        while len(entries) > self.capacity:
            entries.popitem(last=False)
        return placed


def make_decode_step(model: Model, *, mesh=None, db=None, on_trace=None):
    """See ``make_prefill_step`` for the ``mesh`` / ``db`` contract.
    ``on_trace`` (internal; the serving telemetry hook) is called once
    per jit (re)trace of the decode step — for a mesh-placed step that is
    at most once per (batch, cache) shape class."""
    if db is not None and mesh is None:
        mesh = db.mesh
    from repro.launch.mesh import resolve_mesh

    cfg = model.cfg
    mesh = resolve_mesh(mesh)

    def decode_step(params, token, caches, length, enc_out=None):
        if on_trace is not None:
            on_trace()
        logits, caches = model.decode_step(params, token, caches, length, enc_out)
        return logits, caches

    if mesh is None:
        return decode_step
    # enc_out is optional, so a fixed-arity in_shardings tuple cannot be
    # used; place the params explicitly instead — cached per live params
    # object (weakref/identity keyed with LRU eviction), so the per-token
    # hot path re-walks the weight pytree only down to its first leaf and
    # a retired params version never leaks its placed copy.
    pshard = _param_shardings(model, mesh)
    jitted = jax.jit(decode_step)
    placed = _PlacedParamsCache()

    def sharded_decode(params, token, caches, length, enc_out=None):
        return jitted(placed.place(params, pshard), token, caches, length, enc_out)

    sharded_decode._placed_cache = placed  # introspection for tests
    return sharded_decode


# ---------------------------------------------------------------------------
# BucketedPrefill: the session-backed bucketed prefill engine
# ---------------------------------------------------------------------------


class BucketedPrefill:
    """Bucketed prefill over a ``repro.Database`` session: one compiled
    executable per **(batch, seq) bucket**, held in the session's
    executable cache with LRU eviction and hit/evict accounting
    (``db.counters()["cache"]``).

    Requests are rounded up to the smallest configured bucket with the
    same sequence length (zero-padded on the **batch** dim; logits and
    caches are sliced back), so mixed-batch traffic compiles once per
    bucket instead of once per shape. The sequence dim is never padded:
    this repo's models emit last-position-only prefill logits and carry
    unmasked recurrent (conv/SSM) state, so right-padding the sequence
    would score the pad token — pad prompts to a bucketed length in the
    tokenizer instead. ``warmup(params, ...)`` sweeps the configured
    buckets through compilation before traffic arrives.

    ``db`` shares an existing session (its ``max_cache_entries`` bounds
    the cache); without one, a private session is created with
    ``max_entries`` as the bound and ``mesh`` as its active mesh.

    This is the bucketing engine *inside* the serving front door — build
    endpoints with ``db.endpoint(...)`` / ``repro.serve(db, ...)``
    (serving/service.py), which add the async request path, continuous
    batching, decode bucketing and load shedding on top.
    """

    def __init__(
        self,
        model: Model,
        cache_len: int,
        *,
        db=None,
        buckets: Optional[Sequence[Tuple[int, int]]] = None,
        max_entries: int = 8,
        mesh=None,
        on_compile: Optional[Callable[[], None]] = None,
    ):
        if db is None:
            from repro.core.session import Database

            db = Database(mesh=mesh, max_cache_entries=max_entries)
        self.db = db
        self.model = model
        self.cache_len = cache_len
        self.buckets: Optional[List[Tuple[int, int]]] = (
            sorted({(int(b), int(s)) for b, s in buckets}) if buckets else None
        )
        #: telemetry hook: called once per bucket executable built (a
        #: session-cache miss) — the endpoint counts these under
        #: ``serve/prefill/compiles``.
        self.on_compile = on_compile

    def bucket_for(self, batch: int, seq: int) -> Tuple[int, int]:
        """The smallest configured (batch, seq) bucket that fits the
        request — batch rounds up, the sequence length must match a
        bucket exactly (see the class docstring) — or the exact shape
        when no buckets were configured."""
        if not self.buckets:
            return (batch, seq)
        fitting = [
            (b, s) for b, s in self.buckets if b >= batch and s == seq
        ]
        if not fitting:
            raise ValueError(
                f"no bucket fits (batch={batch}, seq={seq}); configured "
                f"buckets: {self.buckets} (batch rounds up, seq must "
                f"match exactly — pad prompts to a bucket length "
                f"upstream)"
            )
        return min(fitting, key=lambda bs: bs[0])

    def max_batch(self, seq: int) -> Optional[int]:
        """The largest configured bucket batch at sequence length ``seq``
        (None in exact-shape mode) — the coalescing cap of the serving
        front door's batch formation."""
        if not self.buckets:
            return None
        fitting = [b for b, s in self.buckets if s == seq]
        return max(fitting) if fitting else 0

    def _compiled(self, bucket: Tuple[int, int]):
        key = ("prefill", id(self.model), self.cache_len, bucket)
        mesh = self.db.mesh

        def build():
            if self.on_compile is not None:
                self.on_compile()
            step = make_prefill_step(self.model, self.cache_len, mesh=mesh)
            # make_prefill_step returns a jitted step when a mesh places
            # the params; jit the plain single-device step ourselves.
            return step if mesh is not None else jax.jit(step)

        return self.db.cached_executable(key, build)

    def _pad_batch(self, batch, bsz: int, bucket: Tuple[int, int]):
        b0 = bucket[0]

        def pad(leaf):
            if (
                not hasattr(leaf, "ndim")
                or leaf.ndim == 0
                or leaf.shape[0] != bsz
                or b0 == bsz
            ):
                return leaf
            return jnp.pad(
                leaf, [(0, b0 - bsz)] + [(0, 0)] * (leaf.ndim - 1)
            )

        return jax.tree_util.tree_map(pad, batch)

    @staticmethod
    def _slice_cache_batch(caches, bsz: int, bucket_b: int):
        """Cut the bucket-padding rows back out of the cache pytree so
        decode continues at the *request* batch. The batch axis follows
        this repo's cache layout (``init_cache``): axis 1 under a
        stacked ``scan`` subtree (axis 0 is the layer axis), axis 0
        elsewhere; leaves without the bucket batch at that axis (e.g.
        scalars) pass through."""
        if bsz == bucket_b:
            return caches

        def cut(path, leaf):
            if not hasattr(leaf, "ndim"):
                return leaf
            axis = 1 if any(
                getattr(p, "key", None) == "scan" for p in path
            ) else 0
            if leaf.ndim > axis and leaf.shape[axis] == bucket_b:
                return jax.lax.slice_in_dim(leaf, 0, bsz, axis=axis)
            return leaf

        return jax.tree_util.tree_map_with_path(cut, caches)

    def prefill(self, params, batch: Dict[str, Any]):
        """Bucketed prefill: pads the request's batch dim to its bucket,
        steps the bucket's cached executable, and slices both the logits
        and the caches' batch dim back to the request batch — decode
        then continues seamlessly at the request batch while the
        compiled executable stays amortized per bucket."""
        tokens = batch["tokens"]
        bsz, seq = int(tokens.shape[0]), int(tokens.shape[1])
        bucket = self.bucket_for(bsz, seq)
        step = self._compiled(bucket)
        logits, caches = step(params, self._pad_batch(batch, bsz, bucket))
        return (
            logits[:bsz],
            self._slice_cache_batch(caches, bsz, bucket[0]),
        )

    def warmup(self, params, *, buckets=None, batch_fn=None) -> None:
        """Compile the given (default: all configured) buckets before
        traffic arrives. ``batch_fn(batch, seq)`` builds the exemplar
        batch; the default is a zero token batch, which only suits
        token-only models — encoder-decoder / vision configs (reading
        ``frames`` / ``patches``) must pass ``batch_fn`` so the warmed
        trace matches real traffic's input structure."""
        todo = buckets if buckets is not None else (self.buckets or ())
        for b, s in todo:
            step = self._compiled((int(b), int(s)))
            ex = (
                batch_fn(int(b), int(s))
                if batch_fn is not None
                else {"tokens": jnp.zeros((int(b), int(s)), jnp.int32)}
            )
            try:
                jax.block_until_ready(step(params, ex))
            except KeyError as e:
                raise ValueError(
                    f"warmup's default exemplar batch carries only "
                    f"'tokens' but the model also reads {e}; pass "
                    f"batch_fn=lambda b, s: {{...}} building the full "
                    f"input batch (e.g. repro.data.batch_for)"
                ) from e
