"""Serving steps: batched prefill and single-token decode.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
one new token against a seq_len-sized cache. Sliding-window layers carry
window-sized caches; MLA carries the compressed (c_kv, k_rope) cache; SSM
layers carry (conv window, state) — each O(1) or O(window) per step.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model, stages_of


def _attn_cache_entry(cfg, kind: str, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.hd() if cfg.n_heads else 0
    if kind in ("mla", "mla_moe"):
        return {
            "kv": {
                "c": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
                "r": jnp.zeros((batch, cache_len, cfg.rope_head_dim), dt),
            }
        }
    if kind in ("attn", "global", "moe", "dec"):
        return {
            "kv": {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "local":
        w = min(cfg.window or cache_len, cache_len)
        return {
            "kv": {
                "k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "mamba1":
        c = cfg.ssm_expand * cfg.d_model
        return {
            "ssm1": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c), dt),
                "ssm": jnp.zeros((batch, c, cfg.ssm_state), jnp.float32),
            }
        }
    if kind in ("mamba2", "mamba2_attn"):
        c = cfg.ssm_expand * cfg.d_model
        nh = c // cfg.ssm_head_dim
        entry = {
            "ssm2": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c + 2 * cfg.ssm_state), dt),
                "ssm": jnp.zeros(
                    (batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
                ),
            }
        }
        if kind == "mamba2_attn":
            entry["shared_kv"] = {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        return entry
    raise ValueError(kind)


def init_cache(cfg, batch: int, cache_len: int):
    """Zero-initialized cache pytree matching Model._run_stages structure."""
    caches = []
    for st in stages_of(cfg):
        entry = {
            f"{i}:{kind}": _attn_cache_entry(cfg, kind, batch, cache_len)
            for i, kind in enumerate(st.pattern)
        }
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (st.repeats,) + x.shape), entry
        )
        tail = [
            _attn_cache_entry(cfg, kind, batch, cache_len) for kind in st.tail
        ]
        caches.append({"scan": stacked, "tail": tail})
    return caches


def make_prefill_step(model: Model, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)

    return prefill_step


def make_decode_step(model: Model):
    cfg = model.cfg

    def decode_step(params, token, caches, length, enc_out=None):
        logits, caches = model.decode_step(params, token, caches, length, enc_out)
        return logits, caches

    return decode_step
