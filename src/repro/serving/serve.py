"""Serving steps: batched prefill and single-token decode.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
one new token against a seq_len-sized cache. Sliding-window layers carry
window-sized caches; MLA carries the compressed (c_kv, k_rope) cache; SSM
layers carry (conv window, state) — each O(1) or O(window) per step.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model, stages_of


def _attn_cache_entry(cfg, kind: str, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.hd() if cfg.n_heads else 0
    if kind in ("mla", "mla_moe"):
        return {
            "kv": {
                "c": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
                "r": jnp.zeros((batch, cache_len, cfg.rope_head_dim), dt),
            }
        }
    if kind in ("attn", "global", "moe", "dec"):
        return {
            "kv": {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "local":
        w = min(cfg.window or cache_len, cache_len)
        return {
            "kv": {
                "k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
            }
        }
    if kind == "mamba1":
        c = cfg.ssm_expand * cfg.d_model
        return {
            "ssm1": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c), dt),
                "ssm": jnp.zeros((batch, c, cfg.ssm_state), jnp.float32),
            }
        }
    if kind in ("mamba2", "mamba2_attn"):
        c = cfg.ssm_expand * cfg.d_model
        nh = c // cfg.ssm_head_dim
        entry = {
            "ssm2": {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, c + 2 * cfg.ssm_state), dt),
                "ssm": jnp.zeros(
                    (batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
                ),
            }
        }
        if kind == "mamba2_attn":
            entry["shared_kv"] = {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            }
        return entry
    raise ValueError(kind)


def init_cache(cfg, batch: int, cache_len: int):
    """Zero-initialized cache pytree matching Model._run_stages structure."""
    caches = []
    for st in stages_of(cfg):
        entry = {
            f"{i}:{kind}": _attn_cache_entry(cfg, kind, batch, cache_len)
            for i, kind in enumerate(st.pattern)
        }
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (st.repeats,) + x.shape), entry
        )
        tail = [
            _attn_cache_entry(cfg, kind, batch, cache_len) for kind in st.tail
        ]
        caches.append({"scan": stacked, "tail": tail})
    return caches


def _param_shardings(model: Model, mesh):
    """NamedSharding tree for the model's parameters on ``mesh`` (the
    launch/sharding.py planner layout)."""
    from repro.launch.sharding import param_pspecs, to_shardings

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # FSDP needs a "data" axis; the 1-axis host-mesh fallback still gets
    # the tensor-parallel rules.
    specs = param_pspecs(params_shape, mesh, fsdp="data" in mesh.axis_names)
    return to_shardings(specs, mesh)


def make_prefill_step(model: Model, cache_len: int, *, mesh=None):
    """``mesh`` (a jax Mesh or a ``launch/mesh.resolve_mesh`` spec string
    such as ``"host"`` / ``"production"``) returns the step jitted with
    the launch/sharding.py parameter layout — ``make_host_mesh`` /
    ``make_production_mesh`` are the canonical constructors."""
    from repro.launch.mesh import resolve_mesh

    mesh = resolve_mesh(mesh)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)

    if mesh is None:
        return prefill_step
    return jax.jit(prefill_step, in_shardings=(_param_shardings(model, mesh), None))


def make_decode_step(model: Model, *, mesh=None):
    """See ``make_prefill_step`` for the ``mesh`` contract."""
    from repro.launch.mesh import resolve_mesh

    cfg = model.cfg
    mesh = resolve_mesh(mesh)

    def decode_step(params, token, caches, length, enc_out=None):
        logits, caches = model.decode_step(params, token, caches, length, enc_out)
        return logits, caches

    if mesh is None:
        return decode_step
    # enc_out is optional, so a fixed-arity in_shardings tuple cannot be
    # used; place the params explicitly instead — cached per params
    # object, so the per-token hot path never re-walks the weight pytree
    # (the cache holds the source params, pinning its identity).
    pshard = _param_shardings(model, mesh)
    jitted = jax.jit(decode_step)
    placed: Dict[int, Tuple[Any, Any]] = {}

    def sharded_decode(params, token, caches, length, enc_out=None):
        hit = placed.get(id(params))
        if hit is None or hit[0] is not params:
            placed.clear()
            placed[id(params)] = (params, jax.device_put(params, pshard))
            hit = placed[id(params)]
        return jitted(hit[1], token, caches, length, enc_out)

    return sharded_decode
