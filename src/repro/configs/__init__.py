"""Architecture registry: one module per assigned architecture.

Every config cites its source in brackets. ``get_config(name)`` returns the
full production config; ``get_config(name).reduced()`` is the smoke-test
variant (≤2 superblocks, d_model≤256, ≤4 experts).
"""

from importlib import import_module

from .base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

ARCH_IDS = (
    "olmoe-1b-7b",
    "gemma3-4b",
    "falcon-mamba-7b",
    "whisper-small",
    "gemma2-9b",
    "deepseek-coder-33b",
    "deepseek-v3-671b",
    "llama3-405b",
    "zamba2-7b",
    "qwen2-vl-72b",
)


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG
