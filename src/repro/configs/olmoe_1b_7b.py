"""olmoe-1b-7b [moe] — 64 experts, top-8, 1B active / 7B total
[arXiv:2409.02060]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,                 # per-expert FFN hidden
    vocab=50304,
    pattern=("moe",),
    n_experts=64,
    top_k=8,
    rope_theta=10_000.0,
    qk_norm=True,              # OLMoE uses QK-norm
)
