"""qwen2-vl-72b [vlm] — M-RoPE (t/h/w sections), dynamic-resolution ViT
frontend is a STUB per assignment (input_specs provides patch embeddings)
[arXiv:2409.12191]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # frequency pairs per t/h/w axis (hd=128)
    vis_seq=256,
    opt_state_dtype="bfloat16",
)
