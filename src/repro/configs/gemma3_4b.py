"""gemma3-4b [dense] — 5:1 local:global attention, 1024-token sliding
window, 128k context [hf:google/gemma-3-1b-pt family].

Deviation noted in DESIGN.md: gemma3 uses rope_theta 1e6 for global and
1e4 for local layers; we use a single 1e6 base.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
)
