"""gemma2-9b [dense] — alternating local(4096)/global attention, attn
logit softcap 50, final logit softcap 30 [arXiv:2408.00118]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    pattern=("local", "global"),
    window=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
)
