"""Architecture configuration schema + the four assigned input shapes."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10_000.0

    # attention pattern: repeating superblock of layer kinds; remainder
    # layers (n_layers % len(pattern)) are emitted unscanned at the end.
    pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None       # sliding window for "local" layers
    logit_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qk_norm: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0             # deepseek-v3: leading dense layers
    capacity_factor: float = 1.25
    d_expert_ff: int = 0               # routed-expert hidden (if ≠ d_ff)
    moe_shard_experts: bool = False    # force expert-buffer sharding hints
                                       # (measured worse in §Perf; optional)

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM
    ssm_state: int = 0
    mamba_version: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 0                 # >0: sequential scan over chunks of
                                       # this length (parallel prefix within)
    ssm_scan_dtype: str = "float32"    # state dtype inside the scan
    ssm_pallas: bool = False           # use the Pallas single-pass scan
                                       # kernel (TPU; interpret on CPU)

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    enc_seq: int = 0

    # VLM (qwen2-vl)
    mrope_sections: Tuple[int, int, int] = ()
    vis_seq: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma: embeddings × sqrt(d_model)
    remat: bool = True
    remat_policy: str = "nothing"      # nothing | dots — what remat saves:
                                       # "dots" keeps matmul outputs (less
                                       # backward recompute, more live bytes)
    attn_chunk: int = 2048             # online-softmax KV block for prefill
    opt_state_dtype: str = "float32"   # bf16 for the largest configs
    scan_unroll: int = 1               # dry-run sets repeats (full unroll) so
                                       # cost_analysis counts loop bodies ×trip

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests: ≤2 superblocks,
        d_model≤256, ≤4 experts, small vocab."""
        small = dict(
            n_layers=min(self.n_layers, 2 * max(1, len(self.pattern))),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            head_dim=64 if self.head_dim else None,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            first_k_dense=min(self.first_k_dense, 1),
            q_lora_rank=min(self.q_lora_rank, 32),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            rope_head_dim=min(self.rope_head_dim, 16),
            nope_head_dim=min(self.nope_head_dim, 32),
            v_head_dim=min(self.v_head_dim, 32),
            encoder_layers=min(self.encoder_layers, 2),
            enc_seq=min(self.enc_seq, 16),
            vis_seq=min(self.vis_seq, 8),
            window=min(self.window, 16) if self.window else None,
            attn_chunk=16,
            dtype="float32",
            remat=False,
        )
        if self.n_kv_heads:
            small["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
