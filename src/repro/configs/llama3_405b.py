"""llama3-405b [dense] — GQA kv=8, 128k vocab, 126 layers
[arXiv:2407.21783]. Optimizer state in bf16 so params+state fit the
single-pod 256×16GB HBM budget (documented in DESIGN.md §hardware)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    pattern=("attn",),
    rope_theta=500_000.0,
    opt_state_dtype="bfloat16",
)
