"""zamba2-7b [hybrid] — Mamba-2 backbone with a *shared* attention+MLP
block interleaved (one parameter set reused at every attention position)
[arXiv:2411.15242]. 81 layers = 13 × (5 mamba2 + 1 mamba2+shared-attn) + 3."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "mamba2_attn"),
    ssm_state=64,
    mamba_version=2,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_width=4,
)
