"""falcon-mamba-7b [ssm] — attention-free Mamba-1, state 16
[arXiv:2410.05355]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    pattern=("mamba1",),
    ssm_state=16,
    mamba_version=1,
    ssm_expand=2,
    conv_width=4,
)
