"""whisper-small [audio] — encoder-decoder; the mel + conv frontend is a
STUB per assignment (input_specs provides precomputed frame embeddings
(B, 1500, 768)) [arXiv:2212.04356].

Deviations noted in DESIGN.md: rotary instead of learned positions;
decode_32k uses a synthetic 32k decoder cache (the real decoder caps at
448 positions).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    n_layers=12,               # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    pattern=("dec",),
    encoder_layers=12,
    enc_seq=1500,
    tie_embeddings=True,       # whisper ties decoder embed/unembed
)
