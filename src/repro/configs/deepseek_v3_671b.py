"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8,
first 3 layers dense [arXiv:2412.19437].

MTP (multi-token prediction) head omitted — noted in DESIGN.md; the MLA
decode path uses the absorbed low-rank formulation so the cache stores
only (c_kv 512 + k_rope 64) per position.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                # dense-layer FFN hidden
    d_expert_ff=2048,          # routed-expert hidden
    vocab=129280,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    first_k_dense=3,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    rope_theta=10_000.0,
    opt_state_dtype="bfloat16",
)
