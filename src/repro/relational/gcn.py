"""Graph convolution as a relational join-aggregate (paper §1, §6).

  h'_dst = Σ_{(src,dst,w) ∈ Edge} w · h_src

Forward: Edge ⋈ Node (gather) + Σ-by-dst (segment sum). Backward — both
∂/∂h (the reversed-edge convolution) and ∂/∂w (per-edge h·g dot) — is the
RA-autodiff-generated query, compiled to gather + segment-sum. The Pallas
segsum kernel is the TPU hot path for the Σ (see kernels/segsum).

Forward and backward step through the ambient ``Database`` session
(``core.session.current()``): the program is built once, lowered per
(graph-size, feature-dim) signature, and reused as a jitted ``Compiled``
across training steps. Under an activated mesh-bearing session
(``with repro.Database(mesh=...).activate():``) the 2-D planner places
the relations on the session's (data × model) mesh, including the edge
CooRelation's nnz row dimension over the data axes
(``data:shard_nnz_*`` plans): the gather join and Σ-by-dst then run
per-shard with the planned scatter collective, so the largest array in
the program — the edge list — never has to fit one device.
``partitioned_edges`` pre-sorts edges by dst (owner partition), which
the planner prices at its edge-cut estimate — or, when the session's
catalog tracks the edge relation's statistics, at the measured
distinct-dst fraction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fra, session
from repro.core.autodiff import ra_autodiff
from repro.core.kernels import ADD, MUL
from repro.core.keys import L, eq_pred, identity_key, jproj
from repro.core.relation import CooRelation, DenseRelation, owner_partition


def partitioned_edges(
    edge_keys, edge_w, n_nodes: int, num_shards: int
) -> CooRelation:
    """Edge relation in the owner-partitioned nnz layout: rows sorted by
    dst (key column 1 — the Σ-by-dst segment key) and padded to a
    ``num_shards`` multiple, so an nnz sharding gives each shard a
    contiguous destination range and the planner prices the scatter at
    ``planner.EDGE_CUT_LOCAL``. Returns the CooRelation to train with —
    its row order is the order edge-weight gradients come back in."""
    rel = CooRelation(
        jnp.asarray(edge_keys, jnp.int32),
        jnp.asarray(edge_w),
        (n_nodes, n_nodes),
    )
    return owner_partition(rel, num_shards, dim=1)


@functools.cache
def _gcn_prog():
    join = fra.Join(
        eq_pred((0, 0)),        # edge.src == node.id
        jproj(L(1)),            # output keyed by dst
        MUL,                    # w · h_src (scalar × chunk)
        fra.scan("Edge", 2),    # differentiable edge weights
        fra.scan("Node", 1),
    )
    q = fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Edge", "Node"))
    prog = ra_autodiff(q)
    scans = {s.name: s.id for s in q.root.table_scans()}
    return prog, scans


@jax.custom_vjp
def gcn_conv(h: jnp.ndarray, edge_keys: jnp.ndarray, edge_w: jnp.ndarray) -> jnp.ndarray:
    """h: (N, D); edge_keys: (E, 2) int32 ⟨src, dst⟩; edge_w: (E,)."""
    prog, _ = _gcn_prog()
    n = h.shape[0]
    env = {
        "Edge": CooRelation(edge_keys, edge_w, (n, n)),
        "Node": DenseRelation(h, 1),
    }
    return session.current().execute(prog.forward, env).data


def _fwd(h, edge_keys, edge_w):
    return gcn_conv(h, edge_keys, edge_w), (h, edge_keys, edge_w)


def _bwd(res, g):
    h, edge_keys, edge_w = res
    prog, scans = _gcn_prog()
    n = h.shape[0]
    edge = CooRelation(edge_keys, edge_w, (n, n))
    node = DenseRelation(h, 1)
    env = {
        "Edge": edge,
        "Node": node,
        f"__fwd_{scans['Edge']}": edge,
        f"__fwd_{scans['Node']}": node,
        "__seed": DenseRelation(g, 1),
    }
    dnode = session.current().execute(prog.grads["Node"], env)
    dedge = session.current().execute(prog.grads["Edge"], env)
    dkeys = np.zeros(edge_keys.shape, dtype=jax.dtypes.float0)
    return dnode.data, dkeys, dedge.values


gcn_conv.defvjp(_fwd, _bwd)
