"""Graph convolution as a relational join-aggregate (paper §1, §6).

  h'_dst = Σ_{(src,dst,w) ∈ Edge} w · h_src

Forward: Edge ⋈ Node (gather) + Σ-by-dst (segment sum). Backward — both
∂/∂h (the reversed-edge convolution) and ∂/∂w (per-edge h·g dot) — is the
RA-autodiff-generated query, compiled to gather + segment-sum. The Pallas
segsum kernel is the TPU hot path for the Σ (see kernels/segsum).

Forward and backward step through the staged engine (core/engine.py):
the program is built once, lowered per (graph-size, feature-dim)
signature, and reused as a jitted ``Compiled`` across training steps.
Under ``core.engine.use_mesh`` the 2-D planner places the relations on
the ambient (data × model) mesh (CooRelation edges stay replicated until
COO nnz-sharding lands — see ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import jit_execute
from repro.core.kernels import ADD, MUL
from repro.core.keys import L, eq_pred, identity_key, jproj
from repro.core.relation import CooRelation, DenseRelation


@functools.cache
def _gcn_prog():
    join = fra.Join(
        eq_pred((0, 0)),        # edge.src == node.id
        jproj(L(1)),            # output keyed by dst
        MUL,                    # w · h_src (scalar × chunk)
        fra.scan("Edge", 2),    # differentiable edge weights
        fra.scan("Node", 1),
    )
    q = fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Edge", "Node"))
    prog = ra_autodiff(q)
    scans = {s.name: s.id for s in q.root.table_scans()}
    return prog, scans


@jax.custom_vjp
def gcn_conv(h: jnp.ndarray, edge_keys: jnp.ndarray, edge_w: jnp.ndarray) -> jnp.ndarray:
    """h: (N, D); edge_keys: (E, 2) int32 ⟨src, dst⟩; edge_w: (E,)."""
    prog, _ = _gcn_prog()
    n = h.shape[0]
    env = {
        "Edge": CooRelation(edge_keys, edge_w, (n, n)),
        "Node": DenseRelation(h, 1),
    }
    return jit_execute(prog.forward, env).data


def _fwd(h, edge_keys, edge_w):
    return gcn_conv(h, edge_keys, edge_w), (h, edge_keys, edge_w)


def _bwd(res, g):
    h, edge_keys, edge_w = res
    prog, scans = _gcn_prog()
    n = h.shape[0]
    edge = CooRelation(edge_keys, edge_w, (n, n))
    node = DenseRelation(h, 1)
    env = {
        "Edge": edge,
        "Node": node,
        f"__fwd_{scans['Edge']}": edge,
        f"__fwd_{scans['Node']}": node,
        "__seed": DenseRelation(g, 1),
    }
    dnode = jit_execute(prog.grads["Node"], env)
    dedge = jit_execute(prog.grads["Edge"], env)
    dkeys = np.zeros(edge_keys.shape, dtype=jax.dtypes.float0)
    return dnode.data, dkeys, dedge.values


gcn_conv.defvjp(_fwd, _bwd)
