"""Relational matmul / linear layer with RA-generated backward.

The weight and activation are arity-0 relations whose single tuple holds
the full (sharded) tensor as its chunk — the degenerate 1×1 blocking of
Appendix A. The forward query is the Σ⋈(MatMul) join-aggregate; auto-diff
produces the Fig.-4 gradient queries (dX = g·Wᵀ, dW = Xᵀ·g) which the
chunked compiler lowers to two einsums. XLA therefore sees exactly the
arithmetic a hand-written backward would emit — the relational machinery
adds zero runtime cost — while the gradient really is the compiled output
of Algorithm 2. Multi-block variants (for the paper's distributed-blocked
benchmarks) are in ``rel_matmul`` with an explicit grid.

Execution goes through the ambient ``Database`` session
(``core.session.current()``): programs are constructed once, lowered per
shape signature, and stepped through jitted ``Compiled`` executables —
repeated training steps never re-walk the FRA graph, and the session's
``compile_auto`` threads committed layouts so repeated steps never
silently reshard either.

Distribution: wrap calls in an activated session —
``with repro.Database(mesh="host:2").activate(): ...`` — and every
execution below compiles against the session's mesh: the 2-D planner
shards the operand block axes over (data × model) and XLA inserts the
collectives; no extra arguments cross the ``custom_vjp`` boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fra, session
from repro.core.autodiff import ra_autodiff
from repro.core.kernels import ADD, MATMUL
from repro.core.keys import L, R, eq_pred, jproj, project_key
from repro.core.relation import DenseRelation


@functools.cache
def _linear_prog():
    """Arity-0 relational matmul: one tuple per relation, chunk = matrix."""
    join = fra.Join(
        eq_pred(),          # keys are both ⟨⟩: trivial match
        jproj(),
        MATMUL,
        fra.scan("X", 0),
        fra.scan("W", 0),
    )
    q = fra.Query(join, inputs=("X", "W"))
    prog = ra_autodiff(q)
    # Resolve the __fwd refs the gradient queries consume: for the optimized
    # matmul RJP these are exactly the forward operands themselves.
    scans = {s.name: s.id for s in q.root.table_scans()}
    return prog, scans


@functools.cache
def _blocked_prog():
    """Multi-block relational matmul over a (BI, BK) × (BK, BJ) grid."""
    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        MATMUL,
        fra.scan("X", 2),
        fra.scan("W", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("X", "W"))
    prog = ra_autodiff(q)
    scans = {s.name: s.id for s in q.root.table_scans()}
    return prog, scans


def _run_grad(prog, scans, env_arrays, seed_rel, arity):
    env = {
        name: DenseRelation(a, arity) for name, a in env_arrays.items()
    }
    env.update(
        {f"__fwd_{scans[name]}": env[name] for name in env_arrays}
    )
    env["__seed"] = seed_rel
    return {
        name: session.current().execute(root, env)
        for name, root in prog.grads.items()
    }


@jax.custom_vjp
def rel_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(m, k) @ (k, n) through the relational engine (arity-0 blocking)."""
    prog, _ = _linear_prog()
    env = {"X": DenseRelation(x, 0), "W": DenseRelation(w, 0)}
    return session.current().execute(prog.forward, env).data


def _mm_fwd(x, w):
    return rel_matmul(x, w), (x, w)


def _mm_bwd(res, g):
    x, w = res
    prog, scans = _linear_prog()
    grads = _run_grad(
        prog, scans, {"X": x, "W": w}, DenseRelation(g, 0), arity=0
    )
    return grads["X"].data, grads["W"].data


rel_matmul.defvjp(_mm_fwd, _mm_bwd)


def rel_linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Linear layer over arbitrary leading batch dims: (..., k) @ (k, n)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    y = rel_matmul(x.reshape(-1, k), w)
    return y.reshape(*lead, w.shape[-1])


@jax.custom_vjp
def rel_matmul_blocked(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Blocked matmul over explicit chunk grids.

    x: (BI, BK, bm, bk), w: (BK, BJ, bk, bn) → (BI, BJ, bm, bn).
    This is the layout the paper's distributed engine stores (Fig. 1); the
    forward einsum contracts both the block axis and the within-chunk axis.
    """
    prog, _ = _blocked_prog()
    env = {"X": DenseRelation(x, 2), "W": DenseRelation(w, 2)}
    return session.current().execute(prog.forward, env).data


def _bmm_fwd(x, w):
    return rel_matmul_blocked(x, w), (x, w)


def _bmm_bwd(res, g):
    x, w = res
    prog, scans = _blocked_prog()
    grads = _run_grad(
        prog, scans, {"X": x, "W": w}, DenseRelation(g, 2), arity=2
    )
    return grads["X"].data, grads["W"].data


rel_matmul_blocked.defvjp(_bmm_fwd, _bmm_bwd)
