"""Embedding lookup as a relational join (gather ≡ key-equality join).

The token stream is a COO relation keyed ⟨position, token-id⟩ with value 1
(the relational one-hot); joining it with the embedding table on
token-id == table-key and aggregating by position is the gather. The
RA-generated backward is the mirrored join: scatter-add of output
cotangents into table rows — the classic embedding gradient, derived by
Algorithm 2 rather than written by hand. Both directions step through the
ambient ``Database`` session (``core.session.current()``): lowered once
per (batch, vocab, dim) signature, jit-cached across steps. Under an
activated mesh-bearing session the 2-D planner places the table's block
axes on the session's (data × model) mesh (the vocab-parallel layout of
launch/sharding.py, derived from the plan instead of a name rule) and
may shard the token-stream CooRelation's nnz rows — one row per
position, so nnz sharding IS batch data parallelism — over the data
axes, with the position-keyed Σ's scatter costed by the planner like any
other collective.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fra, session
from repro.core.autodiff import ra_autodiff
from repro.core.kernels import ADD, MUL
from repro.core.keys import L, eq_pred, jproj, project_key
from repro.core.relation import CooRelation, DenseRelation


@functools.cache
def _embed_prog():
    join = fra.Join(
        eq_pred((1, 0)),        # ids.token == table.row
        jproj(L(0)),            # keyed by position
        MUL,                    # 1.0 × table row
        fra.const("Ids", 2),
        fra.scan("Table", 1),
    )
    q = fra.Query(fra.Agg(project_key(0), ADD, join), inputs=("Table",))
    prog = ra_autodiff(q)
    scans = {s.name: s.id for s in q.root.table_scans()}
    consts = {c.ref: c.id for c in q.root.topo() if isinstance(c, fra.Const)}
    return prog, scans, consts


@jax.custom_vjp
def rel_embed(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """table: (V, D); ids: (B,) int32 → (B, D)."""
    prog, _, _ = _embed_prog()
    b = ids.shape[0]
    keys = jnp.stack([jnp.arange(b, dtype=jnp.int32), ids.astype(jnp.int32)], axis=1)
    env = {
        "Ids": CooRelation(keys, jnp.ones((b,), dtype=table.dtype), (b, table.shape[0])),
        "Table": DenseRelation(table, 1),
    }
    return session.current().execute(prog.forward, env).data


def _fwd(table, ids):
    return rel_embed(table, ids), (table, ids)


def _bwd(res, g):
    table, ids = res
    prog, scans, consts = _embed_prog()
    b = ids.shape[0]
    keys = jnp.stack([jnp.arange(b, dtype=jnp.int32), ids.astype(jnp.int32)], axis=1)
    idrel = CooRelation(keys, jnp.ones((b,), dtype=table.dtype), (b, table.shape[0]))
    trel = DenseRelation(table, 1)
    env = {
        "Ids": idrel,
        "Table": trel,
        f"__fwd_{scans['Table']}": trel,
        f"__fwd_{consts['Ids']}": idrel,
        "__seed": DenseRelation(g, 1),
    }
    dtable = session.current().execute(prog.grads["Table"], env)
    dids = np.zeros(ids.shape, dtype=jax.dtypes.float0)
    return dtable.data, dids


rel_embed.defvjp(_fwd, _bwd)
