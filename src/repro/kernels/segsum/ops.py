"""Public wrapper for the segment-sum kernel: pads E and the segment count
to tile multiples (padding edges carry id -1, dropped by the one-hot)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import segment_sum_ref
from .segsum import segment_sum_pallas


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "bs", "be", "bd", "interpret", "use_pallas"),
)
def segment_sum(
    msg: jnp.ndarray,
    seg: jnp.ndarray,
    num_segments: int,
    *,
    bs: int = 128,
    be: int = 512,
    bd: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    if not use_pallas:
        return segment_sum_ref(msg, seg, num_segments)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, d = msg.shape
    ep = (-e) % be
    if ep:
        msg = jnp.pad(msg, ((0, ep), (0, 0)))
        seg = jnp.pad(seg, (0, ep), constant_values=-1)
    sp = (-num_segments) % bs
    out = segment_sum_pallas(
        msg,
        seg.astype(jnp.int32),
        num_segments + sp,
        bs=bs,
        be=be,
        bd=bd,
        interpret=interpret,
    )
    return out[:num_segments]
