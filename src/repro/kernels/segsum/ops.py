"""Public wrapper for the segment-sum kernel: the ``pallas``/``interpret``
tiers of the engine's ``segment_sum`` dispatch op (core/kernels.py).

``segment_sum(msg, seg, num_segments)`` pads E and the segment count to
tile multiples (padding edges carry id -1, dropped by the one-hot) and
runs the MXU one-hot-matmul kernel (segsum.py); ``use_pallas=False``
short-circuits to the jnp oracle (ref.py).

The wrapper carries a ``jax.custom_vjp`` so reverse-mode AD differentiates
*through* the Pallas forward: the cotangent of ``msg`` is the gather
``g[seg]`` (out-of-range / padding ids contribute zero), matching the VJP
of ``jax.ops.segment_sum`` exactly — so a compiled training step may route
its forward Σ through the kernel and still be jax.grad-differentiable.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import (
    AccumModel,
    BlockModel,
    GridModel,
    KernelContract,
)

from .ref import segment_sum_ref
from .segsum import segment_sum_pallas


def _run(msg, seg, num_segments, bs, be, bd, interpret, use_pallas):
    if not use_pallas:
        return segment_sum_ref(msg, seg, num_segments)
    e, d = msg.shape
    ep = (-e) % be
    if ep:
        msg = jnp.pad(msg, ((0, ep), (0, 0)))
        seg = jnp.pad(seg, (0, ep), constant_values=-1)
    sp = (-num_segments) % bs
    out = segment_sum_pallas(
        msg,
        seg.astype(jnp.int32),
        num_segments + sp,
        bs=bs,
        be=be,
        bd=bd,
        interpret=interpret,
    )
    return out[:num_segments]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def _segment_sum(msg, seg, num_segments, bs, be, bd, interpret, use_pallas):
    return _run(msg, seg, num_segments, bs, be, bd, interpret, use_pallas)


def _fwd(msg, seg, num_segments, bs, be, bd, interpret, use_pallas):
    out = _run(msg, seg, num_segments, bs, be, bd, interpret, use_pallas)
    return out, seg


def _bwd(num_segments, bs, be, bd, interpret, use_pallas, seg, g):
    # out[s] = Σ_e 1[seg_e == s]·msg[e]  ⇒  ∂out/∂msg[e] = g[seg_e];
    # ids outside [0, num_segments) (the -1 padding) received no sum and
    # get a zero cotangent. Segment ids are integral: float0 tangent.
    valid = (seg >= 0) & (seg < num_segments)
    safe = jnp.clip(seg, 0, num_segments - 1)
    dmsg = jnp.where(valid[:, None], g[safe], jnp.zeros((), dtype=g.dtype))
    dseg = np.zeros(seg.shape, dtype=jax.dtypes.float0)
    return dmsg, dseg


_segment_sum.defvjp(_fwd, _bwd)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "bs", "be", "bd", "interpret", "use_pallas"),
)
def segment_sum(
    msg: jnp.ndarray,
    seg: jnp.ndarray,
    num_segments: int,
    *,
    bs: int = 128,
    be: int = 512,
    bd: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Segment-sum of ``msg`` (E, D) by ``seg`` (E,) into ``num_segments``
    rows, on the Pallas one-hot-matmul kernel.

    ``interpret=None`` auto-selects interpreter mode off-TPU; ``bs``/``be``
    /``bd`` are the segment/edge/feature tile sizes (ragged inputs are
    padded up). Differentiable wrt ``msg`` (custom VJP: gather of the
    cotangent at ``seg``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _segment_sum(
        msg, seg.astype(jnp.int32), num_segments, bs, be, bd, interpret, use_pallas
    )


# -- contract ----------------------------------------------------------------


def _grid_model(info: Dict[str, Any], **concrete: Any) -> Optional[GridModel]:
    """The launch geometry ``_run`` produces for a dispatch site at the
    default tiles: E padded to ``be``-multiples (pad ids -1), the segment
    count padded to ``bs``-multiples, edge sweep innermost."""
    e, d = int(info["nnz"]), int(info["dim"])
    s = int(info["num_segments"])
    bs, be, bd = 128, 512, d
    epad = e + (-e) % be
    spad = s + (-s) % bs
    if epad == 0 or spad == 0 or d == 0:
        return None  # zero-nnz / zero-dim sites are guarded before dispatch
    return GridModel(
        grid=(spad // bs, d // bd, epad // be),
        inputs=(
            BlockModel("seg", (epad,), (be,), lambda i, j, k: (k,)),
            BlockModel("msg", (epad, d), (be, bd), lambda i, j, k: (k, j)),
        ),
        output=BlockModel("out", (spad, d), (bs, bd), lambda i, j, k: (i, j)),
        accumulator=AccumModel(axis=2, init_at=0, store="last"),
    )


#: the statically checkable contract of this package (docs/kernels.md;
#: proven by analysis.kernelcheck, cross-checked by the sanitizer tier).
CONTRACT = KernelContract(
    op="segment_sum",
    dtypes="floating",
    accum_dtype="float32",
    masking=(
        "edges padded to the `be` tile carry segment id -1 (COO_PAD_KEY) "
        "and match no one-hot row",
        "segment ids outside [0, num_segments) contribute to no output row",
        "padded segment rows [num_segments, S') are sliced off on return",
    ),
    vjp="gather g[seg] of the cotangent (inline jnp; padding ids get zero)",
    vjp_pairs=(),
    grid_model=_grid_model,
)
