"""Pure-jnp oracle for the segment-sum kernel."""

import jax
import jax.numpy as jnp


def segment_sum_ref(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    """Out-of-range / negative segment ids (padding) are dropped, matching
    the kernel's one-hot behaviour."""
    return jax.ops.segment_sum(msg, seg, num_segments=num_segments)
