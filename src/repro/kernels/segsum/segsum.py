"""Segment-sum kernel — the Σ-by-destination over an edge CooRelation.

This is the aggregation half of the GCN join-agg tree (paper §1/§6). A GPU
engine lowers it to atomic scatter-adds; the TPU has no efficient
random-access scatter, so we ADAPT the insight instead of porting it: the
scatter is re-expressed as a sequence of one-hot × message matmuls that run
on the 128×128 MXU.

  out[s, :]  =  Σ_e 1[seg_e == s] · msg[e, :]
             =  (one-hot(seg))ᵀ @ msg

Grid (num_segments/bs, E/be): for each segment tile s we sweep the edge
tiles (innermost axis) building a (bs, be) one-hot in VREGs and
accumulating onehot @ msg_tile into a VMEM f32 accumulator. Cost is
O(S·E/(bs·be)) MXU issues — dense in E per segment tile, which on TPU
beats serialized scatter for the degree distributions of the paper's
graphs; edges need no sorting at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum_kernel(seg_ref, msg_ref, o_ref, acc_ref, *, bs: int, ne: int):
    # Grid is (segment tile i, feature tile j, edge tile k) with the edge
    # sweep innermost so the (bs, bd) accumulator stays live across it.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(0)
    seg = seg_ref[...]  # (be,) int32 segment ids of this edge tile
    local = seg - i * bs
    onehot = (
        local[None, :] == jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    ).astype(jnp.float32)  # (bs, be)
    acc_ref[...] += jnp.dot(
        onehot, msg_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == ne - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def segment_sum_pallas(
    msg: jnp.ndarray,   # (E, D)
    seg: jnp.ndarray,   # (E,) int32 in [0, num_segments) (pad with -1)
    num_segments: int,
    *,
    bs: int = 128,
    be: int = 512,
    bd: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    e, d = msg.shape
    assert seg.shape == (e,)
    assert e % be == 0 and num_segments % bs == 0, (e, be, num_segments, bs)
    bd = bd or d
    assert d % bd == 0
    ne = e // be

    return pl.pallas_call(
        functools.partial(_segsum_kernel, bs=bs, ne=ne),
        grid=(num_segments // bs, d // bd, ne),
        in_specs=[
            pl.BlockSpec((be,), lambda i, j, k: (k,)),
            pl.BlockSpec((be, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), msg.dtype),
        scratch_shapes=[pltpu.VMEM((bs, bd), jnp.float32)],
        interpret=interpret,
    )(seg, msg)
