from .ops import segment_sum  # noqa: F401
