"""Pallas TPU kernels for the engine's compute hot-spots.

The paper's hot path is the chunked join-aggregate (Σ⋈). On TPU this maps
to two kernels:

  matmul/   — MXU-tiled blocked matmul with VMEM accumulation: the Σ⋈ with
              ⊗ = MatMul over DenseRelations (paper Fig. 4 / Appendix A).
  segsum/   — segment-sum of edge messages: the Σ-by-dst over a CooRelation
              (GCN message passing). TPU-native adaptation: the scatter-add
              a GPU engine would use is re-expressed as one-hot × message
              matmuls so the reduction runs on the MXU instead of relying
              on random-access memory writes the TPU does not have.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with interpret fallback and a custom VJP so
reverse-mode AD differentiates through the Pallas forward), ref.py
(pure-jnp oracle used by tests and served as the ``ref`` dispatch tier).

matmul and segsum are wired into the engine through the kernel dispatch
registry in core/kernels.py: the chunked compiler resolves its
segment-sum and matmul-shaped join-aggregate lowerings against the
registry, which routes them here on TPU (and, when forced, to the
interpret/ref tiers on CPU). See docs/kernels.md for the registry
contract and the authoring walkthrough.
"""
