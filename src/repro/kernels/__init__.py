"""Pallas TPU kernels for the engine's compute hot-spots.

The paper's hot path is the chunked join-aggregate (Σ⋈). On TPU this maps
to two kernels:

  matmul/   — MXU-tiled blocked matmul with VMEM accumulation: the Σ⋈ with
              ⊗ = MatMul over DenseRelations (paper Fig. 4 / Appendix A).
  segsum/   — segment-sum of edge messages: the Σ-by-dst over a CooRelation
              (GCN message passing). TPU-native adaptation: the scatter-add
              a GPU engine would use is re-expressed as one-hot × message
              matmuls so the reduction runs on the MXU instead of relying
              on random-access memory writes the TPU does not have.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with interpret fallback), ref.py (pure-jnp
oracle used by tests).
"""
