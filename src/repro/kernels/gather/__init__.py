"""Row-gather kernel package: the ``gather_join`` dispatch op's
pallas/interpret tiers (ops.py) and jnp oracle (ref.py)."""

from .ops import gather_rows
from .ref import gather_rows_ref

__all__ = ["gather_rows", "gather_rows_ref"]
