"""Public wrapper for the row-gather kernel: the ``pallas``/``interpret``
tiers of the engine's ``gather_join`` dispatch op (core/kernels.py).

``gather_rows(table, rows)`` masks invalid (negative / out-of-range) row
ids to zero rows — the COO pad-and-mask contract — and runs the
scalar-prefetch DMA kernel (gather.py); ``use_pallas=False``
short-circuits to the jnp oracle (ref.py).

The wrapper carries a ``jax.custom_vjp`` so reverse-mode AD differentiates
*through* the Pallas forward, and the gradient stays **in-tier**: the
cotangent of ``table`` is the scatter-add of ``g`` by ``rows`` — exactly
the segment-sum op — routed to the segsum kernel package under the same
``interpret``/``use_pallas`` flags as the forward. A compiled step that
gathers through the DMA kernel therefore back-propagates through the
matching one-hot-matmul scatter kernel, never silently falling back to a
different physical tier.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import (
    BlockModel,
    GridModel,
    Interval,
    KernelContract,
    VjpPair,
)

from .gather import gather_rows_pallas
from .ref import gather_rows_ref


def _run(table, rows, num_rows, interpret, use_pallas):
    if not use_pallas:
        return gather_rows_ref(table, rows)
    if rows.shape[0] == 0:  # empty gather: zero-nnz COO guard
        return jnp.zeros((0, table.shape[1]), dtype=table.dtype)
    valid = (rows >= 0) & (rows < num_rows)
    safe = jnp.clip(rows, 0, max(num_rows - 1, 0)).astype(jnp.int32)
    out = gather_rows_pallas(table, safe, interpret=interpret)
    return jnp.where(valid[:, None], out, jnp.zeros((), table.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _gather_rows(table, rows, num_rows, interpret, use_pallas):
    return _run(table, rows, num_rows, interpret, use_pallas)


def _fwd(table, rows, num_rows, interpret, use_pallas):
    out = _run(table, rows, num_rows, interpret, use_pallas)
    return out, rows


def _bwd(num_rows, interpret, use_pallas, rows, g):
    # out[e] = table[rows_e]  ⇒  dtable = Σ_e 1[rows_e == r]·g[e] — the
    # scatter-add IS the segment-sum op; stay in the forward's tier so
    # gradients run the same physical kernels. Invalid (padding) ids are
    # dropped by the segsum kernels' out-of-range contract.
    if rows.shape[0] == 0:
        dtable = jnp.zeros((num_rows, g.shape[1]), dtype=g.dtype)
    elif use_pallas:
        from repro.kernels.segsum.ops import segment_sum

        dtable = segment_sum(
            g, rows, num_rows, interpret=interpret, use_pallas=True
        )
    else:
        from repro.kernels.segsum.ref import segment_sum_ref

        dtable = segment_sum_ref(g, rows, num_rows)
    drows = np.zeros(rows.shape, dtype=jax.dtypes.float0)
    return dtable, drows


_gather_rows.defvjp(_fwd, _bwd)


@functools.partial(
    jax.jit, static_argnames=("interpret", "use_pallas")
)
def _jitted(table, rows, interpret, use_pallas):
    return _gather_rows(table, rows, table.shape[0], interpret, use_pallas)


def gather_rows(
    table: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Gather rows of ``table`` (N, D) at ``rows`` (E,) on the Pallas
    scalar-prefetch DMA kernel; ids outside ``[0, N)`` (COO padding)
    produce zero rows. ``interpret=None`` auto-selects interpreter mode
    off-TPU. Differentiable wrt ``table`` (custom VJP: same-tier
    segment-sum scatter of the cotangent)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _jitted(table, rows.astype(jnp.int32), interpret, use_pallas)


# -- contract ----------------------------------------------------------------


def _grid_model(
    info: Dict[str, Any], rows: Optional[Any] = None, **concrete: Any
) -> Optional[GridModel]:
    """The scalar-prefetch launch geometry: one program per output row.
    Statically the table block index is only known to lie in the clamp
    range ``[0, N)`` (an Interval); the sanitizer passes the concrete
    ``rows`` to sharpen it into the exact per-step DMA indices."""
    e, n, d = int(info["rows"]), int(info["num_rows"]), int(info["dim"])
    if e == 0 or n == 0 or d == 0:
        return None  # the zero-nnz guard short-circuits before the kernel
    if rows is not None:
        import numpy as np_mod

        safe = np_mod.clip(np_mod.asarray(rows), 0, n - 1)

        def table_map(i):
            return (int(safe[i]), 0)
    else:
        span = Interval(0, n - 1)

        def table_map(i):
            return (span, 0)

    return GridModel(
        grid=(e,),
        inputs=(BlockModel("table", (n, d), (1, d), table_map),),
        output=BlockModel("out", (e, d), (1, d), lambda i: (i, 0)),
        accumulator=None,
    )


def _vjp_info(info: Dict[str, Any]) -> Dict[str, Any]:
    # dtable = Σ_e 1[rows_e == r]·g[e] — the segment-sum dispatch op
    return {
        "nnz": info["rows"],
        "dim": info["dim"],
        "num_segments": info["num_rows"],
        "dtype": info["dtype"],
    }


#: the statically checkable contract of this package (docs/kernels.md;
#: proven by analysis.kernelcheck, cross-checked by the sanitizer tier).
CONTRACT = KernelContract(
    op="gather_join",
    dtypes="floating",
    accum_dtype="none",
    masking=(
        "row ids outside [0, N) (COO padding) are clamped before the DMA "
        "and their output rows zeroed after it",
        "empty gathers (E = 0) short-circuit to zeros before the kernel",
    ),
    vjp="same-tier segment_sum scatter of the cotangent (dispatch op)",
    vjp_pairs=(VjpPair("segment_sum", _vjp_info),),
    grid_model=_grid_model,
)
