"""Row-gather kernel — the edge ⋈ node side of the GCN join-aggregate.

The COO gather join reads, per edge, one row of a dense relation
(``out[e, :] = table[rows[e], :]``). A GPU engine lowers this to a plain
random-access gather; on TPU the idiomatic lowering is a **scalar-prefetch
DMA pipeline**: the row ids are scalar-prefetched so the BlockSpec
index_map can schedule one HBM→VMEM row copy per grid step, and Pallas
double-buffers the copies against the (trivial) compute.

Rows must be pre-clamped to ``[0, num_rows)`` — masking of invalid
(padding) ids happens in the ops.py wrapper, keeping the kernel a pure
copy. The grid is one program per output row; blocking the feature dim /
batching multiple rows per program is TPU tile tuning that lands with
measured numbers (see ROADMAP "tier predicates from measurements").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp  # noqa: F401  (type annotations)
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(rows_ref, table_ref, o_ref):
    # The index_map already steered this program's table block to row
    # rows[i]; the body is the VMEM copy the DMA pipeline overlaps.
    del rows_ref
    o_ref[...] = table_ref[...]


def gather_rows_pallas(
    table: jnp.ndarray,  # (N, D)
    rows: jnp.ndarray,   # (E,) int32 in [0, N)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    e, = rows.shape
    n, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, rows_ref: (rows_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, rows_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, d), table.dtype),
        interpret=interpret,
    )(rows, table)
