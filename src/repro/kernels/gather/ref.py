"""Pure-jnp oracle for the row-gather kernel."""

import jax.numpy as jnp


def gather_rows_ref(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """``out[e] = table[rows[e]]`` with out-of-range / negative row ids
    (COO padding) masked to zero, matching the kernel wrapper's contract.
    Natively differentiable: the VJP of the masked gather is the masked
    scatter-add."""
    n = table.shape[0]
    valid = (rows >= 0) & (rows < n)
    safe = jnp.clip(rows, 0, max(n - 1, 0))
    return jnp.where(valid[:, None], table[safe], jnp.zeros((), table.dtype))
