from .ops import blocked_matmul  # noqa: F401
