"""MXU-tiled matmul kernel — the Σ⋈(MatMul) join-aggregate on TPU.

Grid (M/bm, N/bn, K/bk); the K axis is the innermost (fastest-varying)
grid dimension so the f32 VMEM accumulator for an (i, j) output tile stays
live across the contraction. Tiles default to 128×128×128: MXU-aligned
(the systolic array is 128×128) and small enough that
x-tile + y-tile + acc + out ≈ (128·128·4)·4 B ≈ 256 KiB ≪ 16 MiB VMEM,
leaving room for double-buffered pipelining of the HBM→VMEM copies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """x @ y with explicit VMEM tiling. Shapes must tile evenly (the ops.py
    wrapper pads); dims should be multiples of 128 for MXU alignment."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
