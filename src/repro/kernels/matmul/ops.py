"""Public wrapper for the blocked-matmul kernel.

Pads inputs up to tile multiples, dispatches to the Pallas kernel on TPU
and to interpret mode elsewhere (this container is CPU-only; TPU is the
deployment target). ``use_pallas=False`` falls back to the jnp oracle —
that is what the chunked compiler uses under jit on CPU, keeping the
kernel on the hot path only where it wins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas
from .ref import matmul_ref


def _pad_to(x: jnp.ndarray, m: int, axis: int) -> jnp.ndarray:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "use_pallas")
)
def blocked_matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """x @ y via the MXU-tiled Pallas kernel, padding to tile multiples."""
    if not use_pallas:
        return matmul_ref(x, y)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape[0], y.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y, bk, 0), bn, 1)
    out = matmul_pallas(xp, yp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]
