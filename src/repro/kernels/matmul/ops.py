"""Public wrapper for the blocked-matmul kernel: the ``pallas``/
``interpret`` tiers of the engine's ``blocked_matmul`` dispatch op
(core/kernels.py).

``blocked_matmul(x, y)`` pads both operands up to tile multiples and runs
the MXU-tiled kernel (matmul.py); ``use_pallas=False`` short-circuits to
the jnp oracle (ref.py). ``interpret=None`` auto-selects interpreter mode
off-TPU (this container is CPU-only; TPU is the deployment target).

The wrapper carries a ``jax.custom_vjp`` so reverse-mode AD differentiates
*through* the Pallas forward, and — matching the paper's Fig. 4 optimized
RJP kernels — the backward is two more blocked matmuls on the same tier:
``dX = g @ Yᵀ`` and ``dY = Xᵀ @ g``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels import (
    AccumModel,
    BlockModel,
    GridModel,
    KernelContract,
    VjpPair,
)

from .matmul import matmul_pallas
from .ref import matmul_ref


def _pad_to(x: jnp.ndarray, m: int, axis: int) -> jnp.ndarray:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


def _run(x, y, bm, bn, bk, interpret, use_pallas):
    if not use_pallas:
        return matmul_ref(x, y)
    m, n = x.shape[0], y.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y, bk, 0), bn, 1)
    out = matmul_pallas(xp, yp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _blocked_matmul(x, y, bm, bn, bk, interpret, use_pallas):
    return _run(x, y, bm, bn, bk, interpret, use_pallas)


def _fwd(x, y, bm, bn, bk, interpret, use_pallas):
    return _run(x, y, bm, bn, bk, interpret, use_pallas), (x, y)


def _bwd(bm, bn, bk, interpret, use_pallas, res, g):
    x, y = res
    # Fig. 4 RJP kernels, routed through the same tier as the forward.
    dx = _run(g, y.T, bm, bn, bk, interpret, use_pallas)
    dy = _run(x.T, g, bm, bn, bk, interpret, use_pallas)
    return dx.astype(x.dtype), dy.astype(y.dtype)


_blocked_matmul.defvjp(_fwd, _bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "use_pallas")
)
def blocked_matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """``x @ y`` via the MXU-tiled Pallas kernel, padding to tile
    multiples. ``bm``/``bn``/``bk`` are the output-row/output-col/
    contraction tile sizes. Differentiable (custom VJP: two blocked
    matmuls on the same tier)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _blocked_matmul(x, y, bm, bn, bk, interpret, use_pallas)


# -- contract ----------------------------------------------------------------


def _grid_model(info: Dict[str, Any], **concrete: Any) -> Optional[GridModel]:
    """The launch geometry ``_run`` produces at the default 128³ tiles:
    both operands padded to tile multiples, contraction sweep innermost."""
    m, k, n = int(info["m"]), int(info["k"]), int(info["n"])
    bm = bn = bk = 128
    mp = m + (-m) % bm
    kp = k + (-k) % bk
    np_ = n + (-n) % bn
    if 0 in (mp, kp, np_):
        return None  # degenerate extent: nothing is launched
    return GridModel(
        grid=(mp // bm, np_ // bn, kp // bk),
        inputs=(
            BlockModel("x", (mp, kp), (bm, bk), lambda i, j, kk: (i, kk)),
            BlockModel("y", (kp, np_), (bk, bn), lambda i, j, kk: (kk, j)),
        ),
        output=BlockModel("out", (mp, np_), (bm, bn), lambda i, j, kk: (i, j)),
        accumulator=AccumModel(axis=2, init_at=0, store="last"),
    )


def _vjp_dx_info(info: Dict[str, Any]) -> Dict[str, Any]:
    # dX = g @ Yᵀ: (m, n) @ (n, k)
    return {"m": info["m"], "k": info["n"], "n": info["k"], "dtype": info["dtype"]}


def _vjp_dy_info(info: Dict[str, Any]) -> Dict[str, Any]:
    # dY = Xᵀ @ g: (k, m) @ (m, n)
    return {"m": info["k"], "k": info["m"], "n": info["n"], "dtype": info["dtype"]}


#: the statically checkable contract of this package (docs/kernels.md;
#: proven by analysis.kernelcheck, cross-checked by the sanitizer tier).
CONTRACT = KernelContract(
    op="blocked_matmul",
    dtypes="floating",
    accum_dtype="float32",
    masking=(
        "operands zero-padded to 128-multiples; padded rows/cols multiply "
        "to zero and the output is sliced back to (m, n)",
    ),
    vjp="two same-tier blocked matmuls: dX = g @ Yᵀ, dY = Xᵀ @ g (Fig. 4)",
    vjp_pairs=(
        VjpPair("blocked_matmul", _vjp_dx_info),
        VjpPair("blocked_matmul", _vjp_dy_info),
    ),
    grid_model=_grid_model,
)
