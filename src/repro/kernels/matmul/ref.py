"""Pure-jnp oracle for the blocked matmul kernel."""

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32)
    ).astype(out_dtype)
