"""Selective-scan kernel — the Mamba recurrence h_t = a_t ⊙ h_{t-1} + b_t.

XLA's best lowering (jax.lax.associative_scan) is a log-depth parallel
prefix: ~2·log₂(S) full passes over the (B,S,C,N) state tensors through
HBM. The CUDA kernels the SSM papers ship instead keep the running state
in SRAM and stream the sequence once. We ADAPT that insight to the TPU
memory hierarchy: the TPU grid executes sequentially, so a VMEM scratch
accumulator carries h across *time-tile* grid steps — giving exactly one
HBM read of (a, b) and one write of h (3 passes total vs ~2·log₂S ≈ 24
for S = 4 k), with the recurrence itself running in VREGs over a
(bt, bc·N) block.

Grid: (B, C/bc, S/bt), time innermost (sequential on TPU). Scratch: the
(bc, N) running state, persisting across time tiles of the same (B, C)
program; re-zeroed when a new (batch, channel-block) starts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h_ref, state, *, bt: int):
    # a_ref/b_ref/h_ref blocks: (1, bt, bc, N); state: (bc, N) f32
    @pl.when(pl.program_id(2) == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)   # (bt, bc, N)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    state[...] = jax.lax.fori_loop(0, bt, step, state[...])


def ssm_scan_pallas(
    a: jnp.ndarray,   # (B, S, C, N)
    b: jnp.ndarray,   # (B, S, C, N)
    *,
    bt: int = 256,
    bc: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, s, c, n = a.shape
    assert b.shape == a.shape, (a.shape, b.shape)
    assert s % bt == 0 and c % bc == 0, (s, bt, c, bc)

    spec = pl.BlockSpec((1, bt, bc, n), lambda ib, ic, it: (ib, it, ic, 0))
    return pl.pallas_call(
        functools.partial(_scan_kernel, bt=bt),
        grid=(bsz, c // bc, s // bt),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((bc, n), jnp.float32)],
        interpret=interpret,
    )(a, b)
