from .ops import ssm_scan  # noqa: F401
from .ref import ssm_scan_ref  # noqa: F401
