"""Pure-jnp oracle for the selective scan: the parallel-prefix form."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t ⊙ h_{t-1} + b_t along axis 1, h_{-1} = 0."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    return jax.lax.associative_scan(combine, (a, b), axis=1)[1]
