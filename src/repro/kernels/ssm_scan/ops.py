"""Public wrapper for the selective-scan kernel, with a custom VJP whose
backward is itself a (time-reversed) selective scan:

    forward   h_t = a_t ⊙ h_{t-1} + b_t
    backward  ĝ_t = ĥ_t + a_{t+1} ⊙ ĝ_{t+1}      (reverse scan)
              ∂b_t = ĝ_t
              ∂a_t = ĝ_t ⊙ h_{t-1}

so training runs two single-pass kernels + one elementwise multiply —
the same 3-passes-per-direction HBM profile as the forward.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels import (
    AccumModel,
    BlockModel,
    GridModel,
    KernelContract,
)

from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan_pallas


def _pad_bt(s: int, bt: int) -> int:
    return min(bt, s) if s % bt else bt


def _run(a, b, *, bt, bc, interpret, use_pallas):
    if not use_pallas:
        return ssm_scan_ref(a, b)
    bsz, s, c, n = a.shape
    # shrink tiles to divisors (smoke-test shapes)
    while s % bt:
        bt //= 2
    while c % bc:
        bc //= 2
    if bt < 1 or bc < 1:
        return ssm_scan_ref(a, b)
    return ssm_scan_pallas(a, b, bt=bt, bc=bc, interpret=interpret)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5)
)
def ssm_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bt: int = 256,
    bc: int = 8,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Selective scan ``h_t = a_t ⊙ h_{t-1} + b_t`` over axis 1.

    ``a``, ``b``: (B, S, C, N) — batch, sequence, channels, state.
    ``bt``/``bc`` are the time/channel tile sizes (shrunk to divisors for
    ragged smoke-test shapes); ``use_pallas=False`` falls back to the
    associative-scan oracle (ref.py). Differentiable: the custom VJP runs
    a time-reversed scan of the same kernel (see module docstring), so
    training keeps the single-pass HBM profile in both directions.

    Unlike segsum/matmul this kernel is not a compiler lowering target —
    the models layer (models/ssm.py) calls it directly — so it has no
    entry in the core/kernels.py dispatch registry.
    """
    return _run(a, b, bt=bt, bc=bc, interpret=interpret, use_pallas=use_pallas)


def _fwd(a, b, bt, bc, interpret, use_pallas):
    h = _run(a, b, bt=bt, bc=bc, interpret=interpret, use_pallas=use_pallas)
    return h, (a, h)


def _bwd(bt, bc, interpret, use_pallas, res, hbar):
    a, h = res
    # decay shifted one step left: a_{t+1}, zero at the end
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    g = _run(
        jnp.flip(a_next, axis=1),
        jnp.flip(hbar, axis=1),
        bt=bt, bc=bc, interpret=interpret, use_pallas=use_pallas,
    )
    g = jnp.flip(g, axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return (g * h_prev).astype(a.dtype), g.astype(a.dtype)


ssm_scan.defvjp(_fwd, _bwd)


# -- contract ----------------------------------------------------------------


def _grid_model(info: Dict[str, Any], **concrete: Any) -> Optional[GridModel]:
    """The launch geometry ``_run`` produces: tiles shrunk to divisors,
    time innermost so the (bc, N) running state persists across the time
    tiles of one (batch, channel-block) program."""
    bsz = int(info["batch"])
    s, c, n = int(info["seq"]), int(info["channels"]), int(info["state"])
    bt, bc = int(info.get("bt", 256)), int(info.get("bc", 8))
    while s % bt:
        bt //= 2
    while c % bc:
        bc //= 2
    if bt < 1 or bc < 1 or 0 in (bsz, s, c, n):
        return None  # ragged shape: the wrapper falls back to the oracle
    shape = (bsz, s, c, n)
    block = (1, bt, bc, n)

    def spec(ib, ic, it):
        return (ib, it, ic, 0)

    return GridModel(
        grid=(bsz, c // bc, s // bt),
        inputs=(
            BlockModel("a", shape, block, spec),
            BlockModel("b", shape, block, spec),
        ),
        output=BlockModel("h", shape, block, spec),
        # the running state is re-zeroed when a new (batch, channel-block)
        # program starts; every time tile stores its own output block
        accumulator=AccumModel(axis=2, init_at=0, store="every"),
    )


#: the statically checkable contract of this package (docs/kernels.md).
#: ssm_scan is not a dispatch op — the models layer calls it directly —
#: so the contract has no registry entries, only the grid-model proof.
CONTRACT = KernelContract(
    op="ssm_scan",
    dtypes="floating",
    accum_dtype="float32",
    masking=(
        "tile sizes shrink to divisors of (S, C): no padding; shapes that "
        "cannot tile fall back to the associative-scan oracle",
    ),
    vjp="time-reversed scan of the same kernel (custom VJP)",
    vjp_pairs=(),
    grid_model=_grid_model,
)
