"""Checkpointing: flat npz of the (params, opt_state, step) pytree.

Arrays are gathered to host before writing (suitable for the single-host
container; on a real pod this would be per-host sharded writes — the path
layout ``<dir>/step_<n>/shard_<host>.npz`` is already per-host)."""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", "?"))))
            for p in path
        )
        out[prefix + key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, params, opt_state) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}_shard_0.npz")
    arrays = _flatten(params, "params/")
    arrays.update(_flatten(opt_state, "opt/"))
    np.savez(path, **arrays)
    return path


def restore_checkpoint(path: str, params_template, opt_template) -> Tuple[Any, Any]:
    """Restore into the templates' pytree structure (shapes must match)."""
    data = np.load(path)

    def fill(tree, prefix):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for pth, leaf in flat:
            key = prefix + "/".join(
                str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", "?"))))
                for p in pth
            )
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), leaves
        )

    return fill(params_template, "params/"), fill(opt_template, "opt/")
