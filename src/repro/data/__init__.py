from .pipeline import synthetic_lm_batches, batch_for  # noqa: F401
from .graphs import synthetic_graph  # noqa: F401
