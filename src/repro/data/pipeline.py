"""Synthetic data pipeline: deterministic, shardable token batches plus the
modality-stub inputs (frame/patch embeddings) for audio/vlm backbones."""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp


def batch_for(cfg, batch: int, seq: int, rng: np.random.Generator) -> Dict:
    """One training batch matching ``cfg``'s modality."""
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), dtype=jnp.int32
        ),
    }
    labels = rng.integers(0, cfg.vocab, size=(batch, seq))
    out["labels"] = jnp.asarray(labels, dtype=jnp.int32)
    if cfg.encoder_layers:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_seq, cfg.d_model)).astype(np.float32),
            dtype=jnp.dtype(cfg.dtype),
        )
    if cfg.vis_seq:
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vis_seq, cfg.d_model)).astype(np.float32),
            dtype=jnp.dtype(cfg.dtype),
        )
    return out


def synthetic_lm_batches(
    cfg, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict]:
    rng = np.random.default_rng(seed)
    while True:
        yield batch_for(cfg, batch, seq, rng)
