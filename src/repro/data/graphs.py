"""Synthetic graph generator for the GCN experiments (paper §6 scaled to
this container): power-law-ish degree distribution, normalized edge
weights with self loops (the paper's Edge relation stores normalized
weights including self-loops)."""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp


def synthetic_graph(
    n_nodes: int,
    n_edges: int,
    n_feat: int,
    n_labels: int,
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish destinations
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = (rng.pareto(2.0, size=n_edges) * n_nodes / 8).astype(np.int64) % n_nodes
    # add self loops
    loops = np.arange(n_nodes)
    src = np.concatenate([src, loops])
    dst = np.concatenate([dst, loops])
    # symmetric normalization w = 1/sqrt(deg(src)·deg(dst))
    deg = np.bincount(dst, minlength=n_nodes) + np.bincount(src, minlength=n_nodes)
    w = 1.0 / np.sqrt(deg[src] * deg[dst]).astype(np.float32)
    keys = np.stack([src, dst], axis=1).astype(np.int32)
    x = rng.normal(size=(n_nodes, n_feat)).astype(np.float32)
    y = rng.integers(0, n_labels, size=n_nodes).astype(np.int32)
    return {
        "edge_keys": jnp.asarray(keys),
        "edge_w": jnp.asarray(w),
        "x": jnp.asarray(x),
        "y": jnp.asarray(y),
        "n_nodes": n_nodes,
    }
