"""repro: auto-differentiation of relational computations (ICML 2023),
grown toward a production-scale JAX system.

The one front door is the **Database session API**::

    import repro

    db = repro.Database()
    db.put("Rx", X, keys=("row", "col"))
    db.put("theta", theta, keys=("col",))
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    loss, grads = handle.step()

See docs/session.md for the quickstart and the catalog/statistics
semantics; the library-level staged executor underneath remains
importable as ``repro.core.engine.RAEngine``.

The same session is the **serving front door**: register a model in
the catalog and serve it with continuous batching through
``db.endpoint("lm", ...)`` / ``repro.serve(db, "lm", ...)`` (see
docs/serving.md), with telemetry under ``db.counters()``.

Exports are resolved lazily (PEP 562) so ``import repro`` stays free of
jax device initialization.
"""

from typing import TYPE_CHECKING

_LAZY = {
    "Database": ("repro.core.session", "Database"),
    "QueryHandle": ("repro.core.session", "QueryHandle"),
    "CatalogError": ("repro.core.session", "CatalogError"),
    "current": ("repro.core.session", "current"),
    "DenseRelation": ("repro.core.relation", "DenseRelation"),
    "CooRelation": ("repro.core.relation", "CooRelation"),
    "RelationStats": ("repro.core.planner", "RelationStats"),
    "SQLError": ("repro.core.sql", "SQLError"),
    "Diagnostic": ("repro.analysis.diagnostics", "Diagnostic"),
    "CheckReport": ("repro.analysis.diagnostics", "CheckReport"),
    "Endpoint": ("repro.serving.service", "Endpoint"),
    "serve": ("repro.serving.service", "serve"),
}

__all__ = sorted(_LAZY)

if TYPE_CHECKING:  # pragma: no cover — static analyzers only
    from repro.core.planner import RelationStats  # noqa: F401
    from repro.core.relation import CooRelation, DenseRelation  # noqa: F401
    from repro.core.session import (  # noqa: F401
        CatalogError,
        Database,
        QueryHandle,
        current,
    )
    from repro.analysis.diagnostics import CheckReport, Diagnostic  # noqa: F401
    from repro.core.sql import SQLError  # noqa: F401
    from repro.serving.service import Endpoint, serve  # noqa: F401


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    import importlib

    return getattr(importlib.import_module(module), attr)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
